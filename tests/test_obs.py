"""Observability: tracer ring, Perfetto export, validator, penalty ledger,
sketch histograms, and end-to-end traced serving (single host + fleet).

Everything runs on the deterministic virtual clock; the traced end-to-end
runs assert the PR's acceptance contract — a drain-complete run yields a
schema-valid Chrome trace with a full submit → batch → launch → complete
causal chain for every admitted request, and penalty shares conserve to
1.0 ± 1e-9 per workload.
"""
import json

import numpy as np
import pytest

from repro.cluster import ClusterConfig, ClusterServer
from repro.cluster.telemetry import _merge_histograms, merge_snapshots
from repro.core import field as F
from repro.core.scheduler import TenantRequest
from repro.core.scheduler.coscheduler import SliceCoScheduler
from repro.obs import (PenaltyLedger, Tracer, chrome_trace,
                       merge_penalty_sections, validate_chrome_trace,
                       write_chrome_trace)
from repro.obs.ledger import SHARE_KEYS
from repro.obs.tracing import ID_STRIDE
from repro.serve import CryptoServer, ServeConfig
from repro.serve.telemetry import BatchRecord, LatencyHistogram, Telemetry

RNG = np.random.default_rng(29)

# Shared compiled-program caches (same pattern as the other serving suites:
# engines are lru-cached process-wide, so these reuse other modules' work).
COS = SliceCoScheduler()
LAZY_COS = SliceCoScheduler(accum="int32_native", d_tile=171,
                            reduction_by_workload={"dilithium": "lazy"})


def _dil_request(tid, d, t=0.0):
    coeffs = np.asarray(RNG.integers(0, F.DILITHIUM_Q, d, dtype=np.uint64),
                        np.uint32)
    return TenantRequest(tid, "dilithium", d, t, coeffs)


def _cfg(**kw):
    kw.setdefault("validate", False)
    kw.setdefault("n_c", 4)
    kw.setdefault("max_age_s", 0.01)
    kw.setdefault("tracing", True)
    return ServeConfig(**kw)


# --- tracer ring buffer --------------------------------------------------------

def test_tracer_ring_bounds_and_drop_count():
    tr = Tracer(capacity=4)
    for i in range(7):
        tr.instant(f"e{i}", float(i))
    assert len(tr.events) == 4
    assert tr.dropped == 3
    assert [e["name"] for e in tr.event_dicts()] == ["e3", "e4", "e5", "e6"]
    snap = tr.snapshot()
    assert snap == {"events": 4, "dropped": 3, "capacity": 4}
    drained = tr.drain()
    assert len(drained) == 4 and not tr.events
    assert tr.dropped == 3          # the drop audit survives a drain
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_tracer_ids_unique_across_hosts():
    """Causal IDs must never collide in a concatenated fleet trace."""
    t_none, t0, t1 = Tracer(), Tracer(host=0), Tracer(host=1)
    ids = [t_none.next_id(), t_none.next_id(),
           t0.next_id(), t0.next_id(), t1.next_id()]
    assert ids == [1, 2, ID_STRIDE + 1, ID_STRIDE + 2, 2 * ID_STRIDE + 1]
    assert len(set(ids)) == len(ids)


def test_tracer_anchor_maps_wall_onto_serving_clock():
    tr = Tracer()
    tr.anchor(100.0)
    w = tr.wall_now()
    assert 100.0 <= w < 100.5       # perf_counter delta since anchor is tiny


# --- Perfetto export -----------------------------------------------------------

def test_chrome_trace_pid_tid_mapping_and_metadata():
    tr0, tr1 = Tracer(host=0), Tracer(host=1)
    tr0.begin("window", 1, "warmup", 0.001, track="serve")
    tr0.end("window", 1, "warmup", 0.002, track="serve")
    tr0.counter("queue_depth", 0.001, 3.0)
    tr1.instant("coalesce", 0.0015, track="batcher", args={"rows": 4})
    control = Tracer(host=None)
    control.emit("B", "drain_barrier", 0.003, track="cluster")
    control.emit("E", "drain_barrier", 0.004, track="cluster")
    doc = chrome_trace(tr0.event_dicts() + tr1.event_dicts()
                       + control.event_dicts(), label="fleet")
    rows = doc["traceEvents"]
    # host None → pid 1; host h → pid h+2 (host 0 never collides w/ control)
    pids = {r["pid"] for r in rows}
    assert pids == {1, 2, 3}
    names = {(r["pid"], r["args"]["name"]) for r in rows
             if r["ph"] == "M" and r["name"] == "process_name"}
    assert names == {(1, "fleet"), (2, "fleet host 0"), (3, "fleet host 1")}
    # one thread_name metadata row per (pid, track)
    threads = [r for r in rows if r["ph"] == "M"
               and r["name"] == "thread_name"]
    assert len(threads) == len({(r["pid"], r["tid"]) for r in threads})
    span = next(r for r in rows if r["ph"] == "b")
    assert span["ts"] == pytest.approx(1000.0)      # seconds → µs
    assert span["cat"] == "window" and span["id"] == 1
    inst = next(r for r in rows if r["ph"] == "i")
    assert inst["s"] == "t"
    ctr = next(r for r in rows if r["ph"] == "C")
    assert ctr["args"]["value"] == 3.0
    validate_chrome_trace(doc)      # the export itself must be schema-valid


# --- validator negative cases --------------------------------------------------

def _ev(ph, name, pid=1, tid=1, ts=0.0, **kw):
    return {"ph": ph, "name": name, "pid": pid, "tid": tid, "ts": ts, **kw}


def _chain(*, close_request=True, enqueue=True, close_batch=True,
           launch=True, close_launch=True):
    events = [_ev("b", "req", cat="request", id=1),
              _ev("b", "batch", cat="batch", id=2)]
    if close_batch:
        # the close event's roster is the submit → batch causal link
        events.append(_ev("e", "batch", cat="batch", id=2, ts=0.001,
                          args={"rids": [1] if enqueue else []}))
    if launch:
        events.append(_ev("i", "launch_batches",
                          args={"lid": 3, "bids": [2]}))
        events.append(_ev("b", "launch", cat="launch", id=3))
        if close_launch:
            events.append(_ev("e", "launch", cat="launch", id=3, ts=0.002))
    if close_request:
        events.append(_ev("e", "complete", cat="request", id=1, ts=0.003))
    return {"traceEvents": events}


def test_validator_accepts_full_chain():
    stats = validate_chrome_trace(_chain())
    assert stats == {"events": 7, "requests": 1, "rejects": 0,
                     "batches": 1, "launches": 1}


@pytest.mark.parametrize("broken, match", [
    (dict(close_request=False), "unbalanced"),
    (dict(enqueue=False), "no enqueue link"),
    (dict(close_batch=False), "unbalanced"),
    (dict(launch=False), "never reached a launch"),
    (dict(close_launch=False), "unbalanced"),
])
def test_validator_rejects_broken_chains(broken, match):
    with pytest.raises(ValueError, match=match):
        validate_chrome_trace(_chain(**broken))


def test_validator_structural_errors():
    with pytest.raises(ValueError, match="missing 'ph'"):
        validate_chrome_trace({"traceEvents": [{"name": "x"}]})
    with pytest.raises(ValueError, match="unknown phase"):
        validate_chrome_trace({"traceEvents": [_ev("Z", "x")]})
    with pytest.raises(ValueError, match="bad ts"):
        validate_chrome_trace({"traceEvents": [_ev("i", "x", ts=-1.0)]})
    with pytest.raises(ValueError, match="without open 'b'"):
        validate_chrome_trace(
            {"traceEvents": [_ev("e", "x", cat="launch", id=9)]})
    with pytest.raises(ValueError, match="empty stack"):
        validate_chrome_trace({"traceEvents": [_ev("E", "x")]})
    with pytest.raises(ValueError, match="unclosed sync"):
        validate_chrome_trace({"traceEvents": [_ev("B", "x")]})
    with pytest.raises(ValueError, match="missing args.value"):
        validate_chrome_trace({"traceEvents": [_ev("C", "x", args={})]})
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace([])


# --- penalty ledger ------------------------------------------------------------

DIL_PROFILE = {"reduction": "eager", "data_limbs": 3, "tw_limbs": 3,
               "n_channels": 1, "n_folds": 9, "n_diag": 1}


def test_ledger_shares_conserve():
    led = PenaltyLedger(m_tile=128)
    led.observe_launch(workload="dilithium", d=128, live_rows=5,
                       launched_rows=8, n_batches=2, service_s=1e-3,
                       profile=DIL_PROFILE, k_occupancy=0.8)
    led.observe_launch(workload="dilithium", d=256, live_rows=8,
                       launched_rows=8, n_batches=1, service_s=0.0,
                       profile=DIL_PROFILE)
    snap = led.snapshot()
    w = snap["dilithium"]
    assert w["launches"] == 2 and w["batches"] == 3
    assert w["live_rows"] == 13 and w["launched_rows"] == 16
    assert w["reduction_modes"] == {"eager": 2}
    assert abs(sum(w["shares"].values()) - 1.0) <= 1e-9
    assert w["cycles"]["total"] == pytest.approx(
        sum(w["cycles"][k] for k in SHARE_KEYS))
    # every bin is non-negative and padding dominates at 5/128 M fill
    assert all(w["cycles"][k] >= 0.0 for k in SHARE_KEYS)
    assert w["cycles"]["spatial_pad"] > w["cycles"]["mxu_productive"]
    assert PenaltyLedger().snapshot() == {}


def test_merge_penalty_sections_exact():
    a, b = PenaltyLedger(), PenaltyLedger()
    a.observe_launch(workload="dilithium", d=128, live_rows=4,
                     launched_rows=8, n_batches=1, service_s=2e-3,
                     profile=DIL_PROFILE)
    b.observe_launch(workload="dilithium", d=128, live_rows=7,
                     launched_rows=8, n_batches=2, service_s=1e-3,
                     profile={**DIL_PROFILE, "reduction": "lazy",
                              "n_folds": 1})
    b.observe_launch(workload="bn254", d=64, live_rows=2, launched_rows=2,
                     n_batches=1, service_s=1e-3,
                     profile={**DIL_PROFILE, "data_limbs": 4, "tw_limbs": 4,
                              "n_channels": 9})
    sa, sb = a.snapshot(), b.snapshot()
    merged = merge_penalty_sections([sa, None, sb, {}])
    assert set(merged) == {"dilithium", "bn254"}
    dil = merged["dilithium"]
    assert dil["launches"] == 2 and dil["batches"] == 3
    assert dil["reduction_modes"] == {"eager": 1, "lazy": 1}
    for k in SHARE_KEYS:        # raw bins add exactly, no float re-derivation
        assert dil["cycles"][k] == (sa["dilithium"]["cycles"][k]
                                    + sb["dilithium"]["cycles"][k])
    for w in merged.values():
        assert abs(sum(w["shares"].values()) - 1.0) <= 1e-9


# --- sketch histograms ---------------------------------------------------------

def test_histogram_sketch_collapse_and_bounds():
    h = LatencyHistogram(sketch_bound=8)
    xs = [float(x) for x in RNG.lognormal(-4.0, 1.0, 50)]
    for x in xs:
        h.observe(x)
    assert h.sketching and len(h) == 50
    exact = LatencyHistogram()
    for x in xs:
        exact.observe(x)
    s = h.summary()
    assert s["count"] == 50
    assert s["mean_s"] == pytest.approx(np.mean(xs))
    assert s["max_s"] == max(xs)
    srt, g = np.sort(xs), LatencyHistogram.GAMMA * (1 + 1e-12)
    for q in (50, 95, 99):
        # bucket midpoint sits within one GAMMA ratio of the order
        # statistics bracketing the exact (interpolated) quantile
        rank = (q / 100.0) * (len(xs) - 1)
        lo, hi = srt[int(np.floor(rank))], srt[int(np.ceil(rank))]
        assert lo / g <= h.percentile(q) <= hi * g
    with pytest.raises(RuntimeError, match="collapsed"):
        h.samples
    state = h.sketch_state()
    assert state["gamma"] == LatencyHistogram.GAMMA
    assert sum(state["buckets"].values()) + state["zero"] == 50
    assert all(isinstance(k, str) for k in state["buckets"])
    with pytest.raises(ValueError):
        LatencyHistogram(sketch_bound=0)


def test_histogram_zero_and_exact_mode_unchanged():
    h = LatencyHistogram(sketch_bound=2)
    for x in (0.0, -1e-9, 0.01, 0.02):
        h.observe(x)
    assert h.sketching
    assert h.percentile(0) == 0.0           # virtual-clock zeros stay zeros
    exact = LatencyHistogram()              # no bound → reservoir forever
    for x in range(1000):
        exact.observe(x / 1000.0)
    assert not exact.sketching and len(exact.samples) == 1000


def test_merge_histograms_sketch_paths():
    xs = [float(x) for x in RNG.lognormal(-4.0, 0.7, 40)]
    exact_a, exact_b = LatencyHistogram(), LatencyHistogram()
    sk = LatencyHistogram(sketch_bound=4)
    for x in xs[:20]:
        exact_a.observe(x)
    for x in xs[20:]:
        exact_b.observe(x)
        sk.observe(x)
    # all-exact → exact merge
    m = _merge_histograms([exact_a.summary(True), exact_b.summary(True)])
    assert m["merged_exact"] is True and m["count"] == 40
    whole = LatencyHistogram()
    for x in xs:
        whole.observe(x)
    assert m["p99_s"] == pytest.approx(whole.percentile(99), rel=1e-9)
    # one sketched host → bucket-wise merge, exact count/mean/max
    m = _merge_histograms([exact_a.summary(True), sk.summary(True)])
    assert m["merged_exact"] is False and m["count"] == 40
    assert m["mean_s"] == pytest.approx(np.mean(xs))
    assert m["max_s"] == max(xs)
    assert m["p50_s"] == pytest.approx(
        whole.percentile(50), rel=LatencyHistogram.GAMMA - 1.0 + 0.05)
    # gamma disagreement is a hard error, not silent corruption
    bad = sk.summary(True)
    bad["sketch"] = dict(bad["sketch"], gamma=2.0)
    with pytest.raises(ValueError, match="gamma mismatch"):
        _merge_histograms([exact_a.summary(True), bad])


def test_telemetry_sketch_bound_plumbed():
    t = Telemetry(sketch_bound=2)
    for x in (0.01, 0.02, 0.03):
        t.observe_latency(x, queue_wait_s=x / 2)
    snap = t.snapshot(include_samples=True)
    assert "sketch" in snap["latency"] and "samples" not in snap["latency"]
    server = CryptoServer(_cfg(tracing=False, latency_sketch_bound=7),
                          coscheduler=COS)
    assert server.telemetry.latency.sketch_bound == 7


def test_per_workload_reduction_counts_not_first_batch_wins():
    """Regression: the old per-workload ``reduction`` silently reported
    whichever mode the first batch used; now it counts per mode."""
    t = Telemetry()
    rec = dict(workload="dilithium", d_bucket=64, n_c=1, close_reason="full",
               m_occupancy=0.5, k_occupancy=0.5, queue_depth=0,
               service_s=1e-3, age_s=1e-3)
    t.record_batch(BatchRecord(reduction="eager", n_folds=9, **rec))
    t.record_batch(BatchRecord(reduction="lazy", n_folds=1, **rec))
    t.record_batch(BatchRecord(reduction="lazy", n_folds=1, **rec))
    w = t.snapshot()["per_workload"]["dilithium"]
    assert w["reduction_batches"] == {"eager": 1, "lazy": 2}
    assert w["reduction"] == "mixed"
    u = Telemetry()
    u.record_batch(BatchRecord(reduction="lazy", n_folds=1, **rec))
    assert u.snapshot()["per_workload"]["dilithium"]["reduction"] == "lazy"


# --- end-to-end traced serving -------------------------------------------------

def _run_traced(server, n_requests=10, dt=0.0015, end=0.1):
    handles = []
    for i in range(n_requests):
        t = i * dt
        handles.append(server.submit(
            _dil_request(i, 64 if i % 2 else 100, t), now=t))
        server.pump(t)
    server.drain(end)
    return handles


def test_traced_serve_sync_full_causal_chain(tmp_path):
    server = CryptoServer(_cfg(), coscheduler=COS)
    handles = _run_traced(server)
    assert all(h.done() and not h.rejected for h in handles)
    path = tmp_path / "trace.json"
    server.write_trace(str(path))
    stats = validate_chrome_trace(json.load(open(path)))
    assert stats["requests"] == len(handles)
    assert stats["rejects"] == 0
    assert stats["batches"] > 0 and stats["launches"] > 0
    snap = server.telemetry.snapshot()
    assert snap["trace"]["events"] == stats["events"] - sum(
        1 for e in json.load(open(path))["traceEvents"] if e["ph"] == "M")
    assert snap["trace"]["dropped"] == 0
    json.dumps(snap)                # the whole snapshot stays JSON-safe


def test_traced_serve_async_rings_holdback():
    """The hardest dispatch shape — zero-sync pipeline, depth-2 launch
    rings, adaptive controller, λ-holdback — still yields complete causal
    chains once drained."""
    server = CryptoServer(
        _cfg(async_pipeline=True, inflight_depth=2, controller=True,
             holdback_lambda=0.5, slo_deadline_s=1.0, max_age_s=0.004),
        coscheduler=COS)
    handles = _run_traced(server, n_requests=20, dt=0.001)
    assert all(h.done() and not h.rejected for h in handles)
    stats = validate_chrome_trace(chrome_trace(server.trace_events()))
    assert stats["requests"] == 20
    assert stats["launches"] > 0
    names = {e["name"] for e in server.trace_events()}
    assert "queue_depth" in names           # counter track present


def test_traced_reject_needs_no_chain():
    server = CryptoServer(_cfg(), coscheduler=COS)
    server.drain(0.0)
    h = server.submit(_dil_request(0, 64), now=0.001)
    assert h.rejected
    stats = validate_chrome_trace(chrome_trace(server.trace_events()))
    assert stats["rejects"] == 1 and stats["requests"] == 0


def test_trace_capacity_plumbed_and_write_requires_tracing():
    server = CryptoServer(_cfg(trace_capacity=8), coscheduler=COS)
    assert server.tracer.capacity == 8
    off = CryptoServer(_cfg(tracing=False), coscheduler=COS)
    assert off.trace_events() == []
    with pytest.raises(RuntimeError, match="tracing is off"):
        off.write_trace("/tmp/never.json")


def test_penalty_ledger_e2e_conserves_including_lazy():
    server = CryptoServer(
        _cfg(accum="int32_native", d_tile=171,
             reduction_by_workload={"dilithium": "lazy"}),
        coscheduler=LAZY_COS)
    handles = _run_traced(server, n_requests=8)
    assert all(h.done() and not h.rejected for h in handles)
    pen = server.telemetry.snapshot()["penalty"]
    assert set(pen) == {"dilithium"}
    w = pen["dilithium"]
    assert w["reduction_modes"] == {"lazy": w["launches"]}
    assert w["live_rows"] == 8
    assert abs(sum(w["shares"].values()) - 1.0) <= 1e-9
    assert w["cycles"]["total"] > 0.0


def test_cluster_traced_fleet(tmp_path):
    cfg = ClusterConfig(
        n_hosts=2,
        serve=ServeConfig(validate=False, n_c=4, max_age_s=0.004,
                          tracing=True))
    cluster = ClusterServer(cfg)
    handles = []
    for i in range(8):
        t = i * 0.001
        handles.append(cluster.submit(_dil_request(i, 64, t), now=t))
        cluster.pump(t)
    cluster.drain(0.05)
    assert all(h.done() and not h.rejected for h in handles)
    path = tmp_path / "fleet.json"
    cluster.write_trace(str(path))
    doc = json.load(open(path))
    stats = validate_chrome_trace(doc)
    assert stats["requests"] == 8
    # per-host process tracks are distinct and the cluster-control barrier
    # span rides its own process
    pids = {e["pid"] for e in doc["traceEvents"] if e["ph"] != "M"}
    assert {2, 3} <= pids            # host 0 → pid 2, host 1 → pid 3
    barrier = [e for e in doc["traceEvents"]
               if e["name"] == "drain_barrier"]
    assert {e["ph"] for e in barrier} == {"B", "E"}
    assert all(e["pid"] == 1 for e in barrier)
    # merged fleet telemetry carries the merged penalty section
    pen = cluster.snapshot()["merged"]["penalty"]
    assert abs(sum(pen["dilithium"]["shares"].values()) - 1.0) <= 1e-9
