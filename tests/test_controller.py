"""Closed-loop dispatch: adaptive occupancy controller, λ-priced merge
holdback, depth-k launch ring, ladder validation, perf-report diffing, and
the persistent compile cache.

The acceptance obligations of the closed-loop PR live here: the controller
must recover M occupancy above the static floor under a drifting arrival
rate, a held batch must never breach the admission-visible SLO, a depth-k
drain must retire every in-flight launch group (cluster barrier included),
and the whole control plane must stay bit-for-bit equal to the static
offline replay.
"""
import importlib.util
import os

import numpy as np
import pytest

import jax

from repro.core import field as F
from repro.core.scheduler import TenantRequest
from repro.core.scheduler.coscheduler import (MIN_ROW_TILE, SliceCoScheduler,
                                              validate_row_ladder)
from repro.launch.serve import (serve_crypto, serve_crypto_cluster,
                                serve_crypto_online)
from repro.serve import CryptoServer, LoadGenerator, ServeConfig
from repro.serve.controller import AdaptiveController

RNG = np.random.default_rng(31)

LADDER = (4, 8, 16)      # small rungs keep the CPU compile budget low

# One laddered co-scheduler for the whole module: every server (and the
# offline replays) reuses its compiled-program cache, so this suite pays
# for each (workload, d_bucket, rung) program once.
COS = SliceCoScheduler(merge=True, row_ladder=LADDER)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _dil_request(tid, d=64, t=0.0):
    coeffs = np.asarray(RNG.integers(0, F.DILITHIUM_Q, d, dtype=np.uint64),
                        np.uint32)
    return TenantRequest(tid, "dilithium", d, t, coeffs)


def _cfg(**kw):
    kw.setdefault("validate", False)
    kw.setdefault("n_c", 4)
    kw.setdefault("max_age_s", 0.002)
    kw.setdefault("merge_dispatch", True)
    kw.setdefault("row_ladder_max", LADDER[-1])
    return ServeConfig(**kw)


def _run_trace(trace, **kw):
    server = CryptoServer(_cfg(**kw), coscheduler=COS)
    load = LoadGenerator(trace, attach=False).run(server)
    assert not load.rejected
    return server, load


# --- satellite: row-ladder construction validation ------------------------------

def test_row_ladder_rejects_non_monotonic():
    with pytest.raises(ValueError, match="strictly increasing"):
        SliceCoScheduler(row_ladder=(16, 8, 32))
    with pytest.raises(ValueError, match="strictly increasing"):
        validate_row_ladder((8, 4))


def test_row_ladder_rejects_duplicates():
    with pytest.raises(ValueError, match="duplicate rung 8"):
        SliceCoScheduler(row_ladder=(4, 8, 8, 16))


def test_row_ladder_rejects_sub_tile_rungs():
    with pytest.raises(ValueError, match="minimum M-tile"):
        SliceCoScheduler(row_ladder=(1, 8, 16))
    with pytest.raises(ValueError, match="minimum M-tile"):
        validate_row_ladder((0,))
    with pytest.raises(ValueError, match="at least one rung"):
        validate_row_ladder(())
    assert validate_row_ladder((MIN_ROW_TILE, 8)) == (MIN_ROW_TILE, 8)


# --- config validation ----------------------------------------------------------

def test_serve_config_cross_field_validation():
    with pytest.raises(ValueError, match="inflight_depth"):
        CryptoServer(_cfg(inflight_depth=0))
    with pytest.raises(ValueError, match="async_pipeline"):
        CryptoServer(_cfg(inflight_depth=2))          # ring needs async
    with pytest.raises(ValueError, match="controller"):
        CryptoServer(_cfg(holdback_lambda=1.0))       # pricing needs the model
    with pytest.raises(ValueError, match="merge_dispatch"):
        CryptoServer(_cfg(holdback_lambda=1.0, controller=True,
                          merge_dispatch=False))
    with pytest.raises(ValueError, match="holdback_lambda"):
        CryptoServer(_cfg(holdback_lambda=-0.5, controller=True))


def test_controller_parameter_validation():
    kw = dict(ladder=LADDER, n_c=4, max_age_s=0.002)
    with pytest.raises(ValueError, match="alpha"):
        AdaptiveController(alpha=0.0, **kw)
    with pytest.raises(ValueError, match="gain"):
        AdaptiveController(gain=0.0, **kw)
    with pytest.raises(ValueError, match="ladder"):
        AdaptiveController(ladder=(), n_c=4, max_age_s=0.002)


# --- controller unit behaviour --------------------------------------------------

def test_controller_bounds_and_rung_snap():
    ctl = AdaptiveController(ladder=LADDER, n_c=4, max_age_s=0.002,
                             slo_deadline_s=0.05, holdback_slo_fraction=0.5)
    key = ("dilithium", 64)
    assert ctl.target_rows(key) == 4          # floor = n_c
    assert ctl.max_age_s(key) == 0.002        # initial = static value
    # age ceiling is SLO-capped: ≤ fraction × deadline
    assert ctl.max_age_ceil_s <= 0.5 * 0.05 + 1e-12
    # rung snapping clamps to [n_c, ladder top]
    assert ctl._snap_rung(1) == 4
    assert ctl._snap_rung(9) == 16
    assert ctl._snap_rung(1000) == 16


def test_controller_starving_raises_age_overload_lowers_it():
    ctl = AdaptiveController(ladder=LADDER, n_c=4, max_age_s=0.002,
                             gain=0.5, alpha=1.0)
    key = ("dilithium", 64)
    # low fill, shallow queue → starving → age grows toward the ceiling
    ctl.observe_dispatch(key, live_rows=4, queue_depth=0, now=0.0)
    assert ctl.max_age_s(key) == pytest.approx(0.003)
    # deep backlog → overloaded → age shrinks toward the floor, and the
    # backlog itself raises the target rung
    ctl.observe_dispatch(key, live_rows=4, queue_depth=200, now=0.01)
    assert ctl.max_age_s(key) < 0.003
    assert ctl.target_rows(key) == LADDER[-1]
    # cluster depth folds into the setpoint even when the local queue is
    # shallow (gossip says merge partners are en route)
    ctl2 = AdaptiveController(ladder=LADDER, n_c=4, max_age_s=0.002,
                              alpha=1.0)
    ctl2.observe_dispatch(key, live_rows=4, queue_depth=0, now=0.0,
                          cluster_depth=64.0)
    assert ctl2.target_rows(key) == LADDER[-1]
    assert ctl2.snapshot()["cluster_depth_max"] == 64.0


# --- tentpole: convergence under a drifting rate --------------------------------

def _drifting_requests():
    """Deterministic two-phase stream: sparse (400 req/s) then dense
    (8,000 req/s) — the drift that mistunes any static close policy."""
    reqs, t, tid = [], 0.0, 0
    for _ in range(30):                       # phase A: gap 2.5 ms
        reqs.append(_dil_request(tid, 64, t))
        tid += 1
        t += 0.0025
    for _ in range(370):                      # phase B: gap 0.125 ms
        reqs.append(_dil_request(tid, 64, t))
        tid += 1
        t += 0.000125
    return reqs


def test_controller_converges_above_static_m_occupancy_floor():
    """Acceptance: under a drifting Poisson-like rate the m-fill EWMA
    recovers above the static floor (n_c / N_c_max) — the controller grows
    the target rung and age window until launches are tall again."""
    trace = _drifting_requests()       # one trace, byte-identical both runs
    static_srv, static_load = _run_trace(trace, async_pipeline=True)
    adaptive_srv, adaptive_load = _run_trace(trace, async_pipeline=True,
                                             controller=True)
    static_snap = static_srv.telemetry.snapshot()
    adaptive_snap = adaptive_srv.telemetry.snapshot()
    floor = 4 / 128                           # n_c / n_c_max
    cls = adaptive_snap["controller"]["classes"]["dilithium/64"]
    assert cls["target_rows"] == LADDER[-1]   # rung climbed off the floor
    assert cls["max_age_s"] > 0.002           # age grew to fill the window
    assert cls["m_occupancy_ewma"] > 1.5 * floor
    # the static path stays pinned at the floor the paper measures
    assert static_snap["dispatch"]["m_occupancy_mean"] == pytest.approx(
        floor, rel=0.35)
    assert (adaptive_snap["dispatch"]["m_occupancy_mean"]
            > 1.5 * static_snap["dispatch"]["m_occupancy_mean"])
    # fewer, taller launches — same rows
    assert (adaptive_snap["dispatch"]["dispatches"]
            < static_snap["dispatch"]["dispatches"])
    # and bit-for-bit the same per-tenant results
    assert set(adaptive_load.outputs) == set(static_load.outputs)
    for tid, row in static_load.outputs.items():
        np.testing.assert_array_equal(adaptive_load.outputs[tid], row)


# --- tentpole: holdback SLO safety ----------------------------------------------

def _bursty_requests():
    """2-row bursts every 4 ms (each closes by age below target) with two
    long 30 ms silences that strand a held batch past its priced window."""
    reqs, t, tid = [], 0.0, 0
    for burst in range(40):
        reqs.append(_dil_request(tid, 64, t))
        reqs.append(_dil_request(tid + 1, 64, t + 0.0002))
        tid += 2
        t += 0.030 if burst in (15, 31) else 0.004
    return reqs


def test_holdback_audited_and_never_breaches_slo():
    """Acceptance: λ-holdback trades p50 for M fill but the SLO gate's
    deadline survives — no held batch may push the admission-visible
    queue-wait p99 past the deadline, and every hold is audited as exactly
    one win, loss, or drain flush."""
    slo = 0.05
    server, load = _run_trace(
        _bursty_requests(), async_pipeline=True, controller=True,
        holdback_lambda=5.0, slo_deadline_s=slo, holdback_slo_fraction=0.5)
    snap = server.telemetry.snapshot()
    hb = snap["holdback"]
    assert hb["held"] >= 3, hb
    assert hb["wins"] >= 1, hb
    assert hb["losses"] >= 1, hb
    assert hb["wins"] + hb["losses"] + hb["flushed"] == hb["held"], hb
    # pricing bound: no realised hold may exceed its SLO share
    assert hb["hold_s_max"] <= 0.5 * slo + 1e-9, hb
    # the admission-visible p99 (queue wait, virtual clock) survives
    assert snap["queue_wait"]["p99_s"] <= slo, snap["queue_wait"]
    assert all(h.done() and not h.rejected for h in load.handles)


def test_holdback_win_merges_partner_into_one_launch():
    """A predicted partner arriving inside the window merges with the held
    batch into one tall launch (the M-fill win the holdback pays p50 for)."""
    server, _ = _run_trace(_bursty_requests(), async_pipeline=True,
                           controller=True, holdback_lambda=5.0,
                           slo_deadline_s=0.05)
    snap = server.telemetry.snapshot()
    assert snap["holdback"]["wins"] >= 1
    assert snap["dispatch"]["merged_dispatches"] >= 1
    assert any(r.n_batches > 1 for r in server.telemetry.dispatches)


# --- tentpole: depth-k launch ring ----------------------------------------------

def test_ring_holds_k_flights_and_drain_retires_all():
    """inflight_depth = 3 with every submit closing a batch: the ring fills
    to exactly k outstanding launch groups, and drain retires them all."""
    server = CryptoServer(_cfg(n_c=1, async_pipeline=True, inflight_depth=3),
                          coscheduler=COS)
    handles = [server.submit(_dil_request(i, 64, i * 1e-4), now=i * 1e-4)
               for i in range(6)]
    # every submit launched a 1-row batch; the ring holds the newest 3
    assert server.inflight_groups == 3
    assert sum(h.done() for h in handles) == 3     # oldest 3 gathered
    server.drain(0.01)
    assert server.inflight_groups == 0
    assert all(h.done() for h in handles)
    eng = server.cos.engine_for("dilithium", 64)
    for h in handles:
        iso = np.zeros((1, 64), np.uint32)
        iso[0] = h.request.coeffs
        np.testing.assert_array_equal(h.result(), eng.oracle_np(iso)[0])


def test_ring_splits_per_class_and_quiesce_retires_cluster_wide():
    """Bursty multi-class closes ride the ring concurrently (one flight per
    workload class), and the cluster drain barrier leaves zero in-flight
    groups on any host."""
    server = CryptoServer(_cfg(async_pipeline=True, inflight_depth=2,
                               max_age_s=0.002), coscheduler=COS)
    now = 0.0
    for i in range(3):                        # 3 rows in each of 2 classes
        server.submit(_dil_request(10 + i, 64, now), now=now)
        server.submit(_dil_request(20 + i, 100, now), now=now)
    server.pump(0.002)                        # age-close both classes at once
    assert server.inflight_groups == 2        # one flight per class in flight
    server.drain(0.003)
    assert server.inflight_groups == 0

    # cluster barrier: every host's ring must be empty after drain
    trace = [_dil_request(i, 64, i * 0.0002) for i in range(40)]
    load, snap, _ = serve_crypto_cluster(
        hosts=2, trace=trace, validate=False, n_c=4, max_age_s=0.002,
        merge_dispatch=True, row_ladder_max=LADDER[-1], async_pipeline=True,
        inflight_depth=2, controller=True,
        coscheduler_factory=lambda h: COS)
    bar = snap["drain_barrier"]
    assert bar["complete"] and bar["inflight_groups"] == 0
    assert all(h.done() and not h.rejected for h in load.handles)


def test_ring_busy_class_cannot_starve_quiet_class():
    """A class that keeps launching must not pin another class's in-flight
    results in the ring: the quiet class's oldest flight is materialised at
    the next serving event it doesn't launch into."""
    server = CryptoServer(_cfg(n_c=1, async_pipeline=True, inflight_depth=2),
                          coscheduler=COS)
    hb = server.submit(_dil_request(0, 100, 0.0), now=0.0)   # class (dil, 128)
    assert not hb.done()                   # in flight, ring not over depth
    ha = [server.submit(_dil_request(1 + i, 64, 1e-4 * (i + 1)),
                        now=1e-4 * (i + 1)) for i in range(4)]
    # every submit launched class (dil, 64); the (dil, 128) flight was
    # gathered at the first event it sat out — no drain needed
    assert hb.done()
    server.drain(0.01)
    assert server.inflight_groups == 0
    assert all(h.done() for h in ha)


def test_controller_consumes_class_local_depth_not_global():
    """The controller's queue model must see the class's own backlog — a
    busy neighbour class's pending rows must not inflate the depth EWMA
    (which would snap the idle class's target rung to the ladder top)."""
    server = CryptoServer(_cfg(controller=True), coscheduler=COS)
    for i in range(3):                     # 3 rows pile up in (dil, 64)
        server.submit(_dil_request(i, 64, 0.0), now=0.0)
    for i in range(4):                     # (dil, 128) closes full → dispatch
        server.submit(_dil_request(10 + i, 100, 0.0), now=0.0)
    assert server.batcher.depth == 3       # the neighbour backlog is global…
    cls = server.telemetry.snapshot()["controller"]["classes"]["dilithium/128"]
    assert cls["updates"] == 1
    assert cls["depth_ewma"] == 0.0        # …but this class saw its own: 0
    server.drain(0.01)


# --- tentpole: replay parity (single host + N=2 cluster) ------------------------

def _parity_kw(seed):
    return dict(duration_s=0.01, rate_hz=1024, seed=seed, d_uniform=256)


def test_closed_loop_serving_matches_offline_replay_bitforbit():
    """Acceptance: controller + holdback + depth-k ring through the full
    online runtime equals the static-config offline replay bit-for-bit —
    single host and a 2-host cluster with the distributed drain barrier."""
    kw = _parity_kw(29)
    offline_results, n_ops, _ = serve_crypto(validate=False, coscheduler=COS,
                                             **kw)
    offline = {}
    for res in offline_results:
        offline.update(res.outputs)
    COS.drain_dispatch_log()      # keep replay launches out of serve telemetry

    load, snap, _ = serve_crypto_online(
        max_age_s=0.002, validate=False, merge_dispatch=True,
        row_ladder_max=LADDER[-1], async_pipeline=True, controller=True,
        holdback_lambda=1.5, inflight_depth=2, coscheduler=COS, **kw)
    assert set(load.outputs) == set(offline) and n_ops == len(offline)
    for tid, row in offline.items():
        np.testing.assert_array_equal(load.outputs[tid], row)
    assert snap["controller"]["updates"] > 0
    COS.drain_dispatch_log()

    cload, csnap, _ = serve_crypto_cluster(
        hosts=2, max_age_s=0.002, validate=False, merge_dispatch=True,
        row_ladder_max=LADDER[-1], async_pipeline=True, controller=True,
        holdback_lambda=1.5, inflight_depth=2,
        coscheduler_factory=lambda h: COS, **kw)
    assert set(cload.outputs) == set(offline)
    for tid, row in offline.items():
        np.testing.assert_array_equal(cload.outputs[tid], row)
    m = csnap["merged"]
    assert m["requests_served"] == n_ops
    assert "holdback" in m and "controller" in m
    assert m["controller"]["hosts"] == 2
    assert csnap["drain_barrier"]["inflight_groups"] == 0


# --- satellite: persistent compile cache ----------------------------------------

def test_compilation_cache_dir_configures_jax(tmp_path):
    cache_dir = str(tmp_path / "xla-cache")
    before = jax.config.jax_compilation_cache_dir
    try:
        server = CryptoServer(_cfg(n_c=2, compilation_cache_dir=cache_dir),
                              coscheduler=COS)
        assert jax.config.jax_compilation_cache_dir == cache_dir
        assert os.path.isdir(cache_dir)
        h1 = server.submit(_dil_request(0, 64), now=0.0)
        h2 = server.submit(_dil_request(1, 64), now=0.0)
        assert h1.done() and h2.done()
        eng = server.cos.engine_for("dilithium", 64)
        iso = np.zeros((1, 64), np.uint32)
        iso[0] = h1.request.coeffs
        np.testing.assert_array_equal(h1.result(), eng.oracle_np(iso)[0])
    finally:
        jax.config.update("jax_compilation_cache_dir", before)


# --- satellite: perf-report BENCH diffing ---------------------------------------

def _perf_report():
    spec = importlib.util.spec_from_file_location(
        "perf_report", os.path.join(ROOT, "scripts", "perf_report.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _record(configs, env=None):
    base_env = {"backend": "cpu", "device_count": 1, "jax": "0.4.37",
                "platform": "test", "python": "3.10"}
    base_env.update(env or {})
    return {"bench": "dispatch", "schema": 1, "env": base_env,
            "points": [{"config": c, "rows_per_s": r}
                       for c, r in configs.items()]}


def test_perf_report_flags_regressions_past_threshold():
    pr = _perf_report()
    base = _record({"a": 1000.0, "b": 1000.0, "gone": 500.0})
    cand = _record({"a": 850.0, "b": 700.0, "fresh": 123.0})
    rep = pr.diff_records(base, cand, threshold=0.2)
    assert not rep["env_mismatch"]
    by = {r["config"]: r for r in rep["per_config"]}
    assert by["a"]["status"] == "ok"          # −15 % is inside the threshold
    assert by["b"]["status"] == "regression"  # −30 % fails
    assert by["b"]["delta"] == pytest.approx(-0.3)
    assert by["gone"]["status"] == "missing-in-candidate"
    assert by["fresh"]["status"] == "new-in-candidate"
    assert [r["config"] for r in rep["regressions"]] == ["b"]


def test_perf_report_env_mismatch_is_warning_not_signal():
    pr = _perf_report()
    base = _record({"a": 1000.0})
    cand = _record({"a": 100.0}, env={"jax": "0.5.0"})
    rep = pr.diff_records(base, cand, threshold=0.2)
    assert rep["env_mismatch"] == {"jax": ("0.4.37", "0.5.0")}
    assert rep["regressions"]                 # detected…
    # …but the CLI downgrades it (exercised via run_bench_diff exit codes in
    # CI; here we assert the mismatch is reported for the caller to act on)


def test_perf_report_missing_baseline_path_is_clean(tmp_path):
    """An absent --baseline file exits 0 under --dry-run and 2 otherwise —
    never an unhandled traceback."""
    import types
    pr = _perf_report()
    cand = tmp_path / "cand.json"
    cand.write_text(__import__("json").dumps(_record({"a": 1.0})))
    args = dict(bench="dispatch", candidate=str(cand),
                baseline=str(tmp_path / "absent.json"), baseline_rev="HEAD",
                fail_threshold=0.2)
    assert pr.run_bench_diff(types.SimpleNamespace(**args, dry_run=True)) == 0
    assert pr.run_bench_diff(types.SimpleNamespace(**args, dry_run=False)) == 2


def test_perf_report_rejects_mismatched_benches_and_bad_schema():
    pr = _perf_report()
    with pytest.raises(ValueError, match="different benches"):
        pr.diff_records(_record({"a": 1.0}),
                        {**_record({"a": 1.0}), "bench": "serve"})
    with pytest.raises(ValueError, match="missing 'env'"):
        pr.check_record({"bench": "x", "schema": 1, "points": []}, "t")
