"""Online serving runtime: submit/flush/drain, admission, telemetry, parity."""
import json

import numpy as np
import pytest

from repro.core import field as F
from repro.core import workloads as WK
from repro.core.scheduler import TenantRequest
from repro.core.scheduler.coscheduler import SliceCoScheduler
from repro.launch.serve import serve_crypto, serve_crypto_online
from repro.serve import (CryptoServer, LoadGenerator, RejectedError,
                         ServeConfig)
from repro.serve.admission import TokenBucket
from repro.serve.telemetry import LatencyHistogram

RNG = np.random.default_rng(3)

# One co-scheduler for the whole module: its per-(workload, d_bucket) compiled
# programs are exactly what the serving layer is built to reuse, and sharing
# them keeps this suite from recompiling the 9-channel BN254 e2e per test.
COS = SliceCoScheduler()


def _cfg(**kw):
    kw.setdefault("validate", False)
    kw.setdefault("n_c", 4)
    kw.setdefault("max_age_s", 0.01)
    return ServeConfig(**kw)


def _server(**kw):
    return CryptoServer(_cfg(**kw), coscheduler=COS)


def _dil_request(tid, d, t=0.0):
    coeffs = np.asarray(RNG.integers(0, F.DILITHIUM_Q, d, dtype=np.uint64),
                        np.uint32)
    return TenantRequest(tid, "dilithium", d, t, coeffs)


# --- submit / flush / drain ----------------------------------------------------

def test_submit_age_flush_drain():
    server = _server()
    h1 = server.submit(_dil_request(0, 100, 0.000), now=0.000)
    h2 = server.submit(_dil_request(1, 80, 0.002), now=0.002)
    assert not h1.done() and not h2.done()
    assert server.pump(0.005) == 0           # age trigger not reached
    assert server.pump(0.010) == 1           # 10ms after first row → flush
    assert h1.done() and h2.done()
    eng = WK.DilithiumEngine(128)            # pow2 bucket of 100
    for h, d in ((h1, 100), (h2, 80)):
        iso = np.zeros((1, 128), np.uint32)
        iso[0, :d] = h.request.coeffs
        np.testing.assert_array_equal(h.result(), eng.oracle_np(iso)[0])
    assert h1.latency_s >= 0.010             # queued the full age window
    # drain resolves stragglers and stops admission
    h3 = server.submit(_dil_request(2, 64, 0.02), now=0.02)
    assert server.drain(0.021) == 1 and h3.done()
    h4 = server.submit(_dil_request(3, 64, 0.03), now=0.03)
    assert h4.rejected and h4.decision.reason == "draining"


def test_close_on_full():
    server = _server(n_c=2)
    h1 = server.submit(_dil_request(0, 64), now=0.0)
    assert not h1.done()
    h2 = server.submit(_dil_request(1, 64), now=0.0)
    assert h1.done() and h2.done()           # N_c rows → closed on add
    assert server.telemetry.batches[0].close_reason == "full"


def test_close_on_occupancy():
    server = _server(n_c=8, occupancy_close=0.5)
    handles = [server.submit(_dil_request(i, 256), now=0.0) for i in range(4)]
    # 4 × 256 / (8 × 256) = 0.5 ⇒ the 4th add crosses the threshold
    assert all(h.done() for h in handles)
    assert server.telemetry.batches[0].close_reason == "occupancy"
    assert server.telemetry.batches[0].n_c == 4


def test_next_deadline_tracks_oldest_row():
    server = _server(max_age_s=0.01)
    assert server.next_deadline() is None
    server.submit(_dil_request(0, 64), now=0.004)
    assert server.next_deadline() == pytest.approx(0.014)


def test_same_tenant_multiple_rows_in_one_batch():
    """A tenant with several requests in one stacked batch gets each of its
    own rows back (routing is by row position, not tenant id)."""
    server = _server(n_c=2)
    r1, r2 = _dil_request(7, 64), _dil_request(7, 100)
    h1 = server.submit(r1, now=0.0)
    h2 = server.submit(r2, now=0.0)
    server.drain(0.001)
    eng64, eng128 = WK.DilithiumEngine(64), WK.DilithiumEngine(128)
    iso1 = np.zeros((1, 64), np.uint32)
    iso1[0, :64] = r1.coeffs
    iso2 = np.zeros((1, 128), np.uint32)
    iso2[0, :100] = r2.coeffs
    np.testing.assert_array_equal(h1.result(), eng64.oracle_np(iso1)[0])
    np.testing.assert_array_equal(h2.result(), eng128.oracle_np(iso2)[0])
    # same bucket as well: two d=64 rows from one tenant stay distinct
    r3, r4 = _dil_request(9, 64), _dil_request(9, 64)
    server2 = _server(n_c=2)
    h3 = server2.submit(r3, now=0.0)
    h4 = server2.submit(r4, now=0.0)
    iso3 = np.zeros((1, 64), np.uint32)
    iso3[0] = r3.coeffs
    iso4 = np.zeros((1, 64), np.uint32)
    iso4[0] = r4.coeffs
    np.testing.assert_array_equal(h3.result(), eng64.oracle_np(iso3)[0])
    np.testing.assert_array_equal(h4.result(), eng64.oracle_np(iso4)[0])
    # resubmitting an in-flight request object is rejected, not double-served
    server3 = _server(n_c=4)
    r5 = _dil_request(11, 64)
    server3.submit(r5, now=0.0)
    dup = server3.submit(r5, now=0.0)
    assert dup.rejected and dup.decision.reason == "duplicate"


# --- admission control ---------------------------------------------------------

def test_admission_rejects_queue_full():
    server = _server(n_c=64, max_age_s=10.0, max_pending=4)
    handles = [server.submit(_dil_request(i, 64), now=0.0) for i in range(6)]
    ok = [h for h in handles if not h.rejected]
    bad = [h for h in handles if h.rejected]
    assert len(ok) == 4 and len(bad) == 2
    assert all(h.decision.reason == "queue_full" for h in bad)
    assert all(h.decision.retry_after_s > 0 for h in bad)
    with pytest.raises(RejectedError):
        bad[0].result()
    snap = server.telemetry.snapshot()
    assert snap["admission"]["rejected"] == 2
    assert snap["admission"]["by_reason"]["queue_full"] == 2
    # draining still serves the admitted four
    server.drain(0.001)
    assert all(h.done() and not h.rejected for h in ok)


def test_admission_rate_limits_noisy_tenant():
    server = _server(n_c=64, max_age_s=10.0,
                     tenant_rate_hz=10.0, tenant_burst=1)
    h1 = server.submit(_dil_request(0, 64, 0.0), now=0.0)
    h2 = server.submit(_dil_request(0, 64, 0.01), now=0.01)   # 10ms later
    h3 = server.submit(_dil_request(1, 64, 0.01), now=0.01)   # other tenant
    assert not h1.rejected
    assert h2.rejected and h2.decision.reason == "rate_limited"
    assert not h3.rejected                    # per-tenant isolation
    # bucket refills at 10 Hz → admitted again 100ms later
    h4 = server.submit(_dil_request(0, 64, 0.12), now=0.12)
    assert not h4.rejected


def test_admission_slo_gate():
    server = _server(n_c=64, max_age_s=10.0, slo_deadline_s=0.1)
    server.admission.service_rate = 10.0      # pretend: 10 ops/s slice
    h1 = server.submit(_dil_request(0, 64), now=0.0)
    h2 = server.submit(_dil_request(1, 64), now=0.0)
    h3 = server.submit(_dil_request(2, 64), now=0.0)
    assert not h1.rejected and not h2.rejected
    # pending=2 ⇒ predicted wait 0.2s > 0.1s SLO ⇒ fast-fail
    assert h3.rejected and h3.decision.reason == "slo_miss"


def test_backpressure_signal():
    server = _server(n_c=64, max_age_s=10.0, max_pending=10)
    for i in range(7):
        server.submit(_dil_request(i, 64), now=0.0)
    assert not server.under_backpressure
    server.submit(_dil_request(7, 64), now=0.0)
    assert server.under_backpressure          # 8 ≥ 0.8 × 10


def test_token_bucket_refill():
    tb = TokenBucket(rate_hz=10.0, burst=2.0)
    assert tb.try_take(0.0) and tb.try_take(0.0)
    assert not tb.try_take(0.0)
    assert tb.time_until() == pytest.approx(0.1)
    assert not tb.try_take(0.05)              # half a token accrued
    assert tb.try_take(0.11)
    tb2 = TokenBucket(rate_hz=10.0, burst=2.0)
    tb2.try_take(0.0)
    assert tb2.try_take(100.0) and tb2.try_take(100.0)  # refill caps at burst
    assert not tb2.try_take(100.0)


# --- parity with the offline pipeline ------------------------------------------

def test_online_matches_offline_bitforbit():
    """Same trace through serve_crypto (offline replay) and the online
    runtime yields identical per-tenant rows — batching policy changes the
    grouping, never the arithmetic (Property 5.1 carried online)."""
    kw = dict(duration_s=0.01, rate_hz=1024, seed=5, validate=False,
              coscheduler=COS)
    offline_results, n_ops, _ = serve_crypto(**kw)
    offline = {}
    for res in offline_results:
        offline.update(res.outputs)
    load, snap, _ = serve_crypto_online(max_age_s=0.002, **kw)
    assert set(load.outputs) == set(offline) and n_ops == len(offline)
    for tid, row in offline.items():
        np.testing.assert_array_equal(load.outputs[tid], row)
    # mixed trace actually exercised both engines
    assert set(snap["per_workload"]) == {"dilithium", "bn254"}


def test_mixed_eager_lazy_tenants_match_all_eager_offline():
    """Satellite regression for the deferred-reduction serve path: one
    CryptoServer co-schedules lazy (κ-amortised) Dilithium tenants next to
    strictly-eager BN254 tenants; every per-tenant row is bit-for-bit equal
    to the all-eager offline replay of the same trace, HLO validation runs in
    both disciplines, and the telemetry fold counters split eager vs deferred
    stalls per close reason."""
    kw = dict(duration_s=0.01, rate_hz=1024, seed=11, d_uniform=256)
    offline_cos = SliceCoScheduler(accum="int32_native", d_tile=171)
    offline_results, n_ops, _ = serve_crypto(validate=True,
                                             coscheduler=offline_cos, **kw)
    offline = {}
    for res in offline_results:
        offline.update(res.outputs)

    load, snap, _ = serve_crypto_online(
        max_age_s=0.002, validate=True, accum="int32_native", d_tile=171,
        reduction_by_workload={"dilithium": "lazy"}, **kw)
    assert set(load.outputs) == set(offline) and n_ops == len(offline)
    for tid, row in offline.items():
        np.testing.assert_array_equal(load.outputs[tid], row)
    assert set(snap["per_workload"]) == {"dilithium", "bn254"}

    # fold counters: lazy Dilithium (256-bucket, tile 171 → 2 passes) defers
    # to one fold per batch; eager BN254 (64-bucket, 1 pass × 9 channels)
    # folds nine times per batch.
    assert snap["per_workload"]["dilithium"]["reduction"] == "lazy"
    assert snap["per_workload"]["bn254"]["reduction"] == "eager"
    n_dil = snap["per_workload"]["dilithium"]["batches"]
    n_bn = snap["per_workload"]["bn254"]["batches"]
    stalls = snap["reduction_stalls"]
    assert stalls["deferred_folds"] == n_dil * 1
    assert stalls["eager_folds"] == n_bn * 9
    # per-close-reason split is complete and consistent with the totals
    by = stalls["by_close_reason"]
    assert set(by) == set(snap["close_reasons"])
    assert sum(v["eager_folds"] for v in by.values()) == stalls["eager_folds"]
    assert sum(v["deferred_folds"] for v in by.values()) \
        == stalls["deferred_folds"]


def test_coscheduler_mixed_reduction_dispatch_isolated():
    """dispatch_mixed with per-workload reduction: the lazy class's engines
    defer folds, the eager class's do not, and both come back exact."""
    cos = SliceCoScheduler(accum="int32_native", d_tile=171,
                           reduction_by_workload={"dilithium": "lazy"})
    assert cos.reduction_for("dilithium") == "lazy"
    assert cos.reduction_for("bn254") == "eager"
    from repro.core.scheduler import RectangularScheduler
    sched = RectangularScheduler(n_c=2, bucket_granularity=256)
    reqs = [_dil_request(i, 256) for i in range(2)]
    res = cos.dispatch_mixed(sched.plan_batches(reqs))[0]
    assert res.stats["reduction"] == "lazy" and res.stats["n_folds"] == 1
    assert res.stats["n_passes"] == 2
    eng = cos.engine_for("dilithium", 256)
    for r in reqs:
        np.testing.assert_array_equal(res.outputs[r.tenant_id],
                                      eng.oracle_np(r.coeffs[None, :])[0])


# --- telemetry -----------------------------------------------------------------

def test_latency_histogram_percentiles():
    h = LatencyHistogram()
    for v in range(1, 101):
        h.observe(v / 1000.0)
    assert h.percentile(50) == pytest.approx(0.0505)
    assert h.percentile(99) == pytest.approx(0.09901)
    assert h.percentile(100) == pytest.approx(0.1)
    s = h.summary()
    assert s["count"] == 100 and s["p95_s"] > s["p50_s"]
    assert LatencyHistogram().summary()["p99_s"] == 0.0


def test_telemetry_json_roundtrip(tmp_path):
    out = tmp_path / "telemetry.json"
    load, snap, _ = serve_crypto_online(
        duration_s=0.008, rate_hz=1024, seed=2, validate=False,
        max_age_s=0.002, telemetry_out=str(out), coscheduler=COS)
    disk = json.loads(out.read_text())
    assert disk == json.loads(json.dumps(snap))   # snapshot is JSON-faithful
    for key in ("k_occupancy_mean", "m_occupancy_mean", "queue_depth_mean",
                "queue_depth_max", "close_reasons", "per_workload"):
        assert key in disk
    for q in ("p50_s", "p95_s", "p99_s"):
        assert disk["latency"][q] >= 0.0
    assert disk["batches"] > 0
    assert disk["requests_served"] == load.n_served
    assert disk["admission"]["admitted"] == len(load.handles)


def test_loadgen_pumps_between_arrivals():
    """Sparse arrivals: every age deadline between two arrivals fires before
    the next submit, so latency never exceeds max_age + service share."""
    reqs = [_dil_request(0, 64, 0.000), _dil_request(1, 64, 0.050)]
    server = _server(n_c=8, max_age_s=0.005)
    gen = LoadGenerator(reqs, attach=False)
    load = gen.run(server)
    assert load.n_served == 2
    reasons = [b.close_reason for b in server.telemetry.batches]
    assert reasons == ["age", "drain"]
    # the first request left the queue at its age deadline (t=0.005), not at
    # the next arrival (t=0.05) — queue wait is virtual-clock exact
    assert server.telemetry.queue_wait.percentile(100) == pytest.approx(0.005)
