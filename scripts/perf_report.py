"""Perf-trajectory tooling: diff ``BENCH_*.json`` records across commits.

The repo's benchmarks all emit the same record envelope
(:func:`benchmarks.common.perf_record`): an ``env`` stamp plus a list of
measurement points keyed by ``config``.  This script compares a freshly
measured candidate record against a baseline — by default the committed
record at a git revision — and reports per-config ``rows_per_s`` deltas:

  PYTHONPATH=src python scripts/perf_report.py --bench dispatch \
      --candidate fresh.json [--baseline PATH | --baseline-rev HEAD] \
      [--fail-threshold 0.2] [--dry-run]

Exit status is the CI contract: a regression beyond ``--fail-threshold`` on
*comparable environments* exits 1.  When the environments differ (different
backend / jax / machine — the usual case on a CI runner diffing a record
measured elsewhere) every regression is downgraded to a warning, because a
rows/s delta across machines is noise, not signal; the env mismatch itself
is printed loudly.  ``--dry-run`` additionally tolerates a missing
candidate or baseline (schema-checks whatever exists and exits 0), so the
CI step stays green on branches that haven't regenerated records — but a
measured regression on a comparable env still fails, dry or not.

The original §Perf artifact report (roofline deltas over
``artifacts/dryrun``) is kept behind ``--legacy-artifacts``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(REPO, "artifacts", "dryrun")

# env keys that must all match for cross-record timing deltas to be signal
ENV_KEYS = ("backend", "device_count", "jax", "platform", "python")

# penalty-ledger share bins watched for drift between records; an absolute
# move past PENALTY_DRIFT_PP on any bin is printed as a warning (never a
# failure — shares are modeled attribution, not a timing claim, but a silent
# 5-point swing in where the cycles go is exactly the regression the ledger
# exists to surface)
PENALTY_BINS = ("mxu_productive", "arithmetic_stall", "spatial_pad",
                "host_gap")
PENALTY_DRIFT_PP = 0.05


# --- BENCH_* record diffing ---------------------------------------------------

def load_record(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    check_record(doc, path)
    return doc


def load_committed_record(bench: str, rev: str = "HEAD") -> dict | None:
    """The committed ``BENCH_<bench>.json`` at a git revision (None when the
    revision predates the record)."""
    name = f"BENCH_{bench}.json"
    try:
        text = subprocess.run(
            ["git", "show", f"{rev}:{name}"], cwd=REPO, capture_output=True,
            text=True, check=True).stdout
    except (subprocess.CalledProcessError, FileNotFoundError):
        return None
    doc = json.loads(text)
    check_record(doc, f"{rev}:{name}")
    return doc


def check_record(doc: dict, origin: str):
    """Schema guard: every record must carry the shared envelope."""
    for key in ("bench", "schema", "env", "points"):
        if key not in doc:
            raise ValueError(f"{origin}: not a perf record — missing {key!r}")
    if not isinstance(doc["points"], list):
        raise ValueError(f"{origin}: points must be a list")


def env_mismatch(base: dict, cand: dict) -> dict:
    """Differing env keys: {key: (baseline value, candidate value)}."""
    out = {}
    for key in ENV_KEYS:
        b, c = base["env"].get(key), cand["env"].get(key)
        if b != c:
            out[key] = (b, c)
    return out


def penalty_drift(bp: dict, cp: dict) -> list[dict]:
    """Per-workload penalty-share moves past ``PENALTY_DRIFT_PP`` between
    two points that both carry a ``penalty`` section (absent on either side
    → nothing to compare, no drift)."""
    out = []
    base_pen, cand_pen = bp.get("penalty"), cp.get("penalty")
    if not base_pen or not cand_pen:
        return out
    for workload in sorted(base_pen.keys() & cand_pen.keys()):
        bs = base_pen[workload].get("shares", {})
        cs = cand_pen[workload].get("shares", {})
        for bin_name in PENALTY_BINS:
            b, c = bs.get(bin_name), cs.get(bin_name)
            if b is None or c is None:
                continue
            if abs(c - b) > PENALTY_DRIFT_PP:
                out.append({"workload": workload, "bin": bin_name,
                            "base": b, "cand": c, "drift": c - b})
    return out


def diff_records(base: dict, cand: dict, threshold: float = 0.2) -> dict:
    """Per-config rows/s deltas + the regression verdict.

    ``delta`` is the candidate's fractional change (+0.10 = 10 % faster);
    a config is a regression when it slowed by more than ``threshold``.
    Configs present on only one side are reported, never failed — a new
    benchmark axis must not masquerade as a regression.
    """
    if base["bench"] != cand["bench"]:
        raise ValueError(f"comparing different benches: "
                         f"{base['bench']!r} vs {cand['bench']!r}")
    base_pts = {p["config"]: p for p in base["points"] if "rows_per_s" in p}
    cand_pts = {p["config"]: p for p in cand["points"] if "rows_per_s" in p}
    rows, regressions = [], []
    for config in base_pts:
        bp = base_pts[config]
        cp = cand_pts.get(config)
        if cp is None:
            rows.append({"config": config, "status": "missing-in-candidate",
                         "base_rows_per_s": bp["rows_per_s"]})
            continue
        # device-parallel points stamp the device_count they ran under; a
        # rows/s delta across different device counts is a topology change,
        # not a regression — report it, never gate on it
        if bp.get("device_count") != cp.get("device_count"):
            rows.append({"config": config, "status": "incomparable",
                         "base_rows_per_s": bp["rows_per_s"],
                         "cand_rows_per_s": cp["rows_per_s"],
                         "base_device_count": bp.get("device_count"),
                         "cand_device_count": cp.get("device_count")})
            continue
        delta = cp["rows_per_s"] / bp["rows_per_s"] - 1.0
        row = {"config": config, "status": "ok",
               "base_rows_per_s": bp["rows_per_s"],
               "cand_rows_per_s": cp["rows_per_s"], "delta": delta}
        drift = penalty_drift(bp, cp)
        if drift:
            row["penalty_drift"] = drift
        if delta < -threshold:
            row["status"] = "regression"
            regressions.append(row)
        rows.append(row)
    for config in cand_pts.keys() - base_pts.keys():
        rows.append({"config": config, "status": "new-in-candidate",
                     "cand_rows_per_s": cand_pts[config]["rows_per_s"]})
    return {"bench": base["bench"], "threshold": threshold,
            "env_mismatch": env_mismatch(base, cand),
            "per_config": rows, "regressions": regressions}


def print_diff(report: dict):
    print(f"=== BENCH_{report['bench']} "
          f"(fail threshold {report['threshold']:.0%}) ===")
    for key, (b, c) in report["env_mismatch"].items():
        print(f"  WARNING env mismatch {key}: baseline={b!r} "
              f"candidate={c!r} — timing deltas are cross-machine noise")
    for row in sorted(report["per_config"], key=lambda r: r["config"]):
        if row["status"] == "missing-in-candidate":
            print(f"  {row['config']:<28} missing in candidate "
                  f"(baseline {row['base_rows_per_s']:.0f} rows/s)")
        elif row["status"] == "new-in-candidate":
            print(f"  {row['config']:<28} new config "
                  f"({row['cand_rows_per_s']:.0f} rows/s)")
        elif row["status"] == "incomparable":
            print(f"  {row['config']:<28} WARNING incomparable: measured "
                  f"under {row['base_device_count']} vs "
                  f"{row['cand_device_count']} devices — not gated")
        else:
            marker = "  REGRESSION" if row["status"] == "regression" else ""
            print(f"  {row['config']:<28} {row['base_rows_per_s']:10.0f} → "
                  f"{row['cand_rows_per_s']:10.0f} rows/s "
                  f"({row['delta']:+.1%}){marker}")
            for d in row.get("penalty_drift", ()):
                # warning only — share drift never affects the exit status
                print(f"    WARNING penalty drift {d['workload']}/"
                      f"{d['bin']}: {d['base']:.1%} → {d['cand']:.1%} "
                      f"({d['drift']:+.1%}, past the "
                      f"{PENALTY_DRIFT_PP:.0%} watch band)")


def run_bench_diff(args) -> int:
    if args.baseline:
        if not os.path.exists(args.baseline):
            msg = f"baseline record {args.baseline} does not exist"
            if args.dry_run:
                print(f"{msg} — nothing to diff (dry run: ok)")
                return 0
            print(msg, file=sys.stderr)
            return 2
        base = load_record(args.baseline)
    else:
        base = load_committed_record(args.bench, args.baseline_rev)
        if base is None:
            msg = (f"no committed BENCH_{args.bench}.json at "
                   f"{args.baseline_rev}")
            if args.dry_run:
                print(f"{msg} — nothing to diff (dry run: ok)")
                return 0
            print(msg, file=sys.stderr)
            return 2
    if not os.path.exists(args.candidate):
        msg = f"candidate record {args.candidate} does not exist"
        if args.dry_run:
            print(f"{msg} — nothing to diff (dry run: ok)")
            return 0
        print(msg, file=sys.stderr)
        return 2
    report = diff_records(base, load_record(args.candidate),
                          threshold=args.fail_threshold)
    print_diff(report)
    if report["regressions"]:
        if report["env_mismatch"]:
            print(f"{len(report['regressions'])} config(s) slowed past the "
                  f"threshold, but the environments differ — treating as "
                  f"noise, not failing")
            return 0
        print(f"FAIL: {len(report['regressions'])} config(s) regressed "
              f"past {report['threshold']:.0%} on a comparable environment")
        return 1
    print("no regressions past the threshold")
    return 0


# --- ingress frontier acceptance gate -----------------------------------------

def check_frontier(args) -> int:
    """Acceptance gate on the committed tenant-frontier points
    (``bench_serve --tenant-frontier``): every ``frontier_*`` point must
    carry bit-identical scalar/columnar decisions, a columnar speedup of at
    least ``--frontier-speedup-floor``, and — when ``--frontier-floor`` is
    set — a sustained admitted-requests/s at or above it.  The numbers are
    read from the committed record (or ``--candidate``), so the gate is a
    deterministic check of the claims the repo ships, not a re-measurement
    on whatever machine CI landed on."""
    if args.candidate:
        if not os.path.exists(args.candidate):
            print(f"candidate record {args.candidate} does not exist",
                  file=sys.stderr)
            return 2
        doc, origin = load_record(args.candidate), args.candidate
    else:
        doc = load_committed_record(args.bench, args.baseline_rev)
        origin = f"{args.baseline_rev}:BENCH_{args.bench}.json"
        if doc is None:
            print(f"no committed BENCH_{args.bench}.json at "
                  f"{args.baseline_rev}", file=sys.stderr)
            return 2
    pts = [p for p in doc["points"]
           if str(p.get("config", "")).startswith("frontier_")]
    print(f"=== frontier gate on {origin} "
          f"(speedup ≥ {args.frontier_speedup_floor:g}x"
          + (f", admitted/s ≥ {args.frontier_floor:,.0f}"
             if args.frontier_floor else "") + ") ===")
    if not pts:
        print("FAIL: record has no frontier_* points — run "
              "bench_serve --tenant-frontier and commit them",
              file=sys.stderr)
        return 1
    failures = 0
    for p in sorted(pts, key=lambda p: p.get("n_tenants", 0)):
        probs = []
        if not p.get("decisions_equal"):
            probs.append("decisions differ from scalar oracle")
        if p.get("speedup", 0.0) < args.frontier_speedup_floor:
            probs.append(f"speedup {p.get('speedup', 0.0):.2f}x below floor")
        if (args.frontier_floor
                and p.get("admitted_per_s", 0.0) < args.frontier_floor):
            probs.append(f"admitted/s {p.get('admitted_per_s', 0.0):,.0f} "
                         f"below floor")
        mark = "FAIL " + "; ".join(probs) if probs else "ok"
        failures += bool(probs)
        print(f"  {p['config']:<22} {p.get('n_tenants', 0):>9,} tenants  "
              f"{p.get('admitted_per_s', 0.0):>12,.0f} admitted/s  "
              f"{p.get('speedup', 0.0):>6.1f}x  {mark}")
    if failures:
        print(f"FAIL: {failures} frontier point(s) below the acceptance "
              f"floor", file=sys.stderr)
        return 1
    print(f"{len(pts)} frontier point(s) meet the acceptance floor")
    return 0


# --- device-parallel scaling acceptance gate ----------------------------------

def check_scaling(args) -> int:
    """Acceptance gate on the committed device-parallel scaling points
    (``bench_cluster --device-parallel``): for every rate swept, the point
    at the largest host count must show a projected fleet rows/s at least
    ``--scaling-floor`` times the single-host baseline measured in the same
    sweep.  Like the frontier gate, this reads the committed record (or
    ``--candidate``) — it checks the claims the repo ships, it does not
    re-measure."""
    if args.candidate:
        if not os.path.exists(args.candidate):
            print(f"candidate record {args.candidate} does not exist",
                  file=sys.stderr)
            return 2
        doc, origin = load_record(args.candidate), args.candidate
    else:
        doc = load_committed_record(args.bench, args.baseline_rev)
        origin = f"{args.baseline_rev}:BENCH_{args.bench}.json"
        if doc is None:
            print(f"no committed BENCH_{args.bench}.json at "
                  f"{args.baseline_rev}", file=sys.stderr)
            return 2
    pts = [p for p in doc["points"] if p.get("device_parallel")]
    print(f"=== device-scaling gate on {origin} "
          f"(max-N speedup ≥ {args.scaling_floor:g}x) ===")
    if not pts:
        print("FAIL: record has no device-parallel points — run "
              "bench_cluster --device-parallel and commit them",
              file=sys.stderr)
        return 1
    by_rate: dict = {}
    for p in pts:
        by_rate.setdefault(p.get("rate_hz"), []).append(p)
    failures = 0
    for rate in sorted(by_rate, key=lambda r: r or 0):
        sweep = sorted(by_rate[rate], key=lambda p: p.get("hosts", 0))
        base = next((p for p in sweep if p.get("hosts") == 1), None)
        if base is None:
            print(f"  rate {rate}: FAIL no single-host baseline point in "
                  f"the sweep")
            failures += 1
            continue
        for p in sweep:
            speedup = (p["rows_per_s"] / base["rows_per_s"]
                       if base["rows_per_s"] > 0 else 0.0)
            print(f"  {p['config']:<26} hosts={p.get('hosts'):>2} "
                  f"devices={p.get('distinct_devices'):>2} "
                  f"{p['rows_per_s']:>10,.0f} rows/s  x{speedup:.2f}")
        top = sweep[-1]
        speedup = (top["rows_per_s"] / base["rows_per_s"]
                   if base["rows_per_s"] > 0 else 0.0)
        if top.get("hosts", 0) <= 1:
            print(f"  rate {rate}: FAIL sweep never leaves one host")
            failures += 1
        elif speedup < args.scaling_floor:
            print(f"  rate {rate}: FAIL x{speedup:.2f} at "
                  f"hosts={top.get('hosts')} is below the "
                  f"{args.scaling_floor:g}x floor")
            failures += 1
    if failures:
        print(f"FAIL: {failures} device-parallel sweep(s) below the "
              f"scaling floor", file=sys.stderr)
        return 1
    print(f"{len(by_rate)} device-parallel sweep(s) meet the scaling floor")
    return 0


# --- legacy §Perf artifact report ---------------------------------------------

def load(arch, shape, mesh="single", tag=""):
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(ART, f"{arch}__{shape}__{mesh}{suffix}.json")
    if not os.path.exists(path):
        return None
    return json.load(open(path))


def report(arch, shape, tags, mesh="single"):
    base = load(arch, shape, mesh)
    if not base or base["status"] != "ok":
        print(f"{arch} {shape}: baseline missing/not-ok")
        return
    rb = base["roofline"]
    print(f"\n=== {arch} × {shape} ({mesh}) ===")
    print(f"  baseline: t_comp={rb['t_compute_s']:.3e} "
          f"t_mem={rb['t_memory_s']:.3e} t_coll={rb['t_collective_s']:.3e} "
          f"dom={rb['dominant']} compile={base.get('compile_s')}s "
          f"temp={base['memory']['temp_size_in_bytes']/1e9:.1f}GB")
    for tag in tags:
        rec = load(arch, shape, mesh, tag)
        if not rec or rec["status"] != "ok":
            print(f"  {tag:16s}: missing/not-ok "
                  f"({(rec or {}).get('error','')[:60]})")
            continue
        r = rec["roofline"]
        dom_key = {"compute": "t_compute_s", "memory": "t_memory_s",
                   "collective": "t_collective_s"}[rb["dominant"]]
        improve = rb[dom_key] / max(r[dom_key], 1e-15)
        print(f"  {tag:16s}: t_comp={r['t_compute_s']:.3e} "
              f"t_mem={r['t_memory_s']:.3e} t_coll={r['t_collective_s']:.3e} "
              f"dom={r['dominant']} compile={rec.get('compile_s')}s "
              f"temp={rec['memory']['temp_size_in_bytes']/1e9:.1f}GB "
              f"[dominant-term x{improve:.2f}]")


def legacy_artifacts():
    report("aegis_bn254", "serve_256", ["scan", "lazy_int32"])
    report("aegis_bn254", "serve_8k", ["scan"])
    report("llama3_405b", "decode_32k", ["gqa_grouped"])
    report("granite_moe_3b_a800m", "prefill_32k",
           ["moe_replicate", "moe_replicate_gqa"])
    report("llama3_405b", "train_4k", ["remat_nothing", "gqa_grouped"])
    report("internlm2_20b", "decode_32k", ["gqa_grouped"])


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default=None,
                    help="BENCH record name to diff, e.g. 'dispatch'")
    ap.add_argument("--candidate", default=None,
                    help="freshly measured record (JSON path)")
    ap.add_argument("--baseline", default=None,
                    help="baseline record path (default: the committed "
                         "record at --baseline-rev)")
    ap.add_argument("--baseline-rev", default="HEAD",
                    help="git revision holding the committed baseline")
    ap.add_argument("--fail-threshold", type=float, default=0.2,
                    help="fail when any config slows by more than this "
                         "fraction (comparable envs only)")
    ap.add_argument("--dry-run", action="store_true",
                    help="tolerate missing records (CI-safe); measured "
                         "regressions on comparable envs still fail")
    ap.add_argument("--check-frontier", action="store_true",
                    help="gate the committed tenant-frontier points "
                         "(decisions parity + speedup/admitted-rate floors) "
                         "instead of diffing rows_per_s")
    ap.add_argument("--frontier-floor", type=float, default=0.0,
                    help="minimum committed admitted-requests/s per "
                         "frontier point (0 = parity + speedup only)")
    ap.add_argument("--frontier-speedup-floor", type=float, default=5.0,
                    help="minimum committed columnar-vs-scalar speedup per "
                         "frontier point")
    ap.add_argument("--check-scaling", action="store_true",
                    help="gate the committed device-parallel points "
                         "(max-N projected rows/s vs the single-host "
                         "baseline) instead of diffing rows_per_s")
    ap.add_argument("--scaling-floor", type=float, default=1.5,
                    help="minimum speedup at the largest host count of "
                         "each device-parallel sweep")
    ap.add_argument("--legacy-artifacts", action="store_true",
                    help="print the §Perf roofline artifact report instead")
    args = ap.parse_args()

    if args.check_frontier:
        if args.bench is None:
            ap.error("--check-frontier needs --bench (which BENCH record "
                     "holds the frontier points, e.g. 'serve')")
        return check_frontier(args)
    if args.check_scaling:
        if args.bench is None:
            ap.error("--check-scaling needs --bench (which BENCH record "
                     "holds the device-parallel points, e.g. 'cluster')")
        return check_scaling(args)
    if args.bench is None and args.candidate is not None:
        ap.error("--candidate needs --bench (which BENCH record to diff); "
                 "refusing to silently fall back to the artifact report")
    if args.legacy_artifacts or args.bench is None:
        legacy_artifacts()
        return 0
    if args.candidate is None:
        ap.error("--bench needs --candidate (the fresh record to compare)")
    return run_bench_diff(args)


if __name__ == "__main__":
    sys.exit(main())
