"""Before/after diff of tagged §Perf artifacts vs baselines."""
import glob
import json
import os
import sys

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "dryrun")


def load(arch, shape, mesh="single", tag=""):
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(ART, f"{arch}__{shape}__{mesh}{suffix}.json")
    if not os.path.exists(path):
        return None
    return json.load(open(path))


def report(arch, shape, tags, mesh="single"):
    base = load(arch, shape, mesh)
    if not base or base["status"] != "ok":
        print(f"{arch} {shape}: baseline missing/not-ok")
        return
    rb = base["roofline"]
    print(f"\n=== {arch} × {shape} ({mesh}) ===")
    print(f"  baseline: t_comp={rb['t_compute_s']:.3e} "
          f"t_mem={rb['t_memory_s']:.3e} t_coll={rb['t_collective_s']:.3e} "
          f"dom={rb['dominant']} compile={base.get('compile_s')}s "
          f"temp={base['memory']['temp_size_in_bytes']/1e9:.1f}GB")
    for tag in tags:
        rec = load(arch, shape, mesh, tag)
        if not rec or rec["status"] != "ok":
            print(f"  {tag:16s}: missing/not-ok "
                  f"({(rec or {}).get('error','')[:60]})")
            continue
        r = rec["roofline"]
        dom_key = {"compute": "t_compute_s", "memory": "t_memory_s",
                   "collective": "t_collective_s"}[rb["dominant"]]
        improve = rb[dom_key] / max(r[dom_key], 1e-15)
        print(f"  {tag:16s}: t_comp={r['t_compute_s']:.3e} "
              f"t_mem={r['t_memory_s']:.3e} t_coll={r['t_collective_s']:.3e} "
              f"dom={r['dominant']} compile={rec.get('compile_s')}s "
              f"temp={rec['memory']['temp_size_in_bytes']/1e9:.1f}GB "
              f"[dominant-term x{improve:.2f}]")


if __name__ == "__main__":
    report("aegis_bn254", "serve_256", ["scan", "lazy_int32"])
    report("aegis_bn254", "serve_8k", ["scan"])
    report("llama3_405b", "decode_32k", ["gqa_grouped"])
    report("granite_moe_3b_a800m", "prefill_32k",
           ["moe_replicate", "moe_replicate_gqa"])
    report("llama3_405b", "train_4k", ["remat_nothing", "gqa_grouped"])
    report("internlm2_20b", "decode_32k", ["gqa_grouped"])
